"""Fused grouped-MoE path: `stamp_quant_grouped_matmul` kernel vs the
unfused oracle (occupancy masking, empty buckets, capacity padding),
`moe_ffn_fused` vs the reference `moe_ffn` (bit-identical routing, odd
sequence lengths, capacity overflow, pad-tail groups), the call-counter
proof that fused MoE prefill issues zero reference expert einsums, the
router-stats telemetry ride-along, expert-parallel sharding of the
prepared int8 buffers, and the single-branch chunk-attention regression
(the XLA fallback must not evaluate flash AND chunked per row)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_platform_name", "cpu")

from jax.sharding import PartitionSpec as P

from repro.core.stamp import (StampConfig, prepare_linear, stamp_fake_quant,
                              token_quantize)
from repro.kernels import ops, ref
from repro.models import layers as L
from repro.models import lm
from repro.obs import quantstats as QS
from repro.serving import kvcache as KV
from repro.sharding import ShardingPolicy


def rand(shape, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape).astype(np.float32) * scale)


def rel_err(a, b):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    return float(np.linalg.norm(a - b) / np.linalg.norm(b))


def make_expert_weight(e, k, n, seed=0):
    """Stacked (E, k, n) signed int8 codes + (E, 1, n) scale / shifted zp
    via the production `prepare_linear` (per-output-channel, per-expert)."""
    w = rand((e, k, n), seed=seed, scale=0.05)
    p = prepare_linear(w, bits=8)
    return p.qw, p.sw, p.zw, w


def make_dispatch(b, e, cap, d, counts, seed=0):
    """Quantized capacity buckets with the first ``counts[i, eg]`` rows
    occupied (the contiguous-prefix layout `moe_route` guarantees)."""
    x = rand((b, e, cap, d), seed=seed)
    qx, sx, zx = token_quantize(x.reshape(b, e * cap, d))
    return (qx.reshape(b, e, cap, d), sx.reshape(b, e, cap, 1),
            zx.reshape(b, e, cap, 1), jnp.asarray(counts, jnp.int32))


class TestGroupedKernel:
    """Pallas kernel (interpret mode) vs the pure-jnp oracle."""

    CASES = [
        # b, e, cap, d, f, counts, block_c, block_f
        (1, 4, 8, 32, 64, [[8, 5, 0, 8]], 8, 32),      # empty bucket
        (2, 2, 16, 32, 64, [[16, 3], [0, 16]], 8, 64),
        (1, 4, 10, 32, 96, [[10, 7, 1, 0]], 8, 96),    # C pads 10 -> 16
        (1, 2, 8, 64, 128, [[8, 8]], 128, 512),        # bc clamps to cap
    ]

    @pytest.mark.parametrize("b,e,cap,d,f,counts,bc,bf", CASES)
    def test_matches_oracle(self, b, e, cap, d, f, counts, bc, bf):
        qx, sx, zx, cnt = make_dispatch(b, e, cap, d, counts, seed=1)
        qg, sg, zg, _ = make_expert_weight(e, d, f, seed=2)
        qu, su, zu, _ = make_expert_weight(e, d, f, seed=3)
        qd, sd, zd, _ = make_expert_weight(e, f, d, seed=4)
        args = (qx, sx, zx, cnt, qg, sg, zg, qu, su, zu, qd, sd, zd)
        y = ops.stamp_quant_grouped_matmul(*args, block_c=bc, block_f=bf,
                                           interpret=True)
        yr = ref.stamp_quant_grouped_matmul_ref(*args, block_f=bf)
        assert y.shape == (b, e, cap, d)
        # the oracle derives the silu-mul requantize codes from an f32
        # einsum while the kernel uses exact int32 GEMMs — .5-boundary
        # code flips bound the gap, not kernel indexing
        assert rel_err(y, yr) < 2e-3

    def test_rows_past_count_exactly_zero(self):
        qx, sx, zx, cnt = make_dispatch(1, 4, 8, 32, [[8, 5, 0, 2]], seed=5)
        qg, sg, zg, _ = make_expert_weight(4, 32, 64, seed=6)
        qu, su, zu, _ = make_expert_weight(4, 32, 64, seed=7)
        qd, sd, zd, _ = make_expert_weight(4, 64, 32, seed=8)
        y = ops.stamp_quant_grouped_matmul(
            qx, sx, zx, cnt, qg, sg, zg, qu, su, zu, qd, sd, zd,
            block_c=8, block_f=32, interpret=True)
        slot = np.arange(8)[None, None, :]
        empty = slot >= np.asarray(cnt)[:, :, None]
        assert np.all(np.asarray(y)[empty] == 0.0)
        assert np.all(np.asarray(y)[~empty] != 0.0)

    def test_registered_in_contract_checker(self):
        """Satellite: the capture registry proves KC001–KC005 on the
        concrete occupancy prefetch table (incl. an empty bucket)."""
        from repro.kernels.specs import KERNEL_EXAMPLES, kernel_spec
        assert "stamp_matmul.grouped" in KERNEL_EXAMPLES
        ex = kernel_spec("stamp_matmul.grouped")
        cap = ex.captures[0]
        assert cap.num_scalar_prefetch == 1
        table = cap.prefetch[0]
        assert 0 in table          # the checker sees the empty-bucket clamp


class TestFusedMoEParity:
    """`moe_ffn_fused` vs the reference `moe_ffn` running the SAME
    prepared-int8 expert weights (dequantized for the reference) — the gap
    is the token quantize + in-kernel requantize only."""

    def _setup(self, bsz, seq, d, f, e, seed=0):
        x = rand((bsz, seq, d), seed=seed)
        gate_w = rand((d, e), seed=seed + 1)
        prep, deq = {}, {}
        for name, (k, n, s) in {"g": (d, f, 2), "u": (d, f, 3),
                                "d": (f, d, 4)}.items():
            qw, sw, zw, _ = make_expert_weight(e, k, n, seed=seed + s)
            prep[name] = {"iq": qw, "isw": sw, "izw": zw}
            deq[name] = (qw.astype(jnp.float32) - zw) * sw
        return x, gate_w, prep, deq

    CASES = [
        # bsz, seq, d, f, e, k, cf, group_size
        (2, 37, 32, 64, 4, 2, 1.25, 16),    # odd seq, pad-tail group
        (1, 64, 32, 64, 4, 2, 1.0, 64),
        (2, 33, 32, 64, 8, 2, 2.0, 32),     # ample capacity
        (1, 48, 64, 128, 4, 1, 1.25, 48),   # top-1
    ]

    @pytest.mark.parametrize("bsz,seq,d,f,e,k,cf,gs", CASES)
    def test_fused_matches_reference(self, bsz, seq, d, f, e, k, cf, gs):
        x, gate_w, prep, deq = self._setup(bsz, seq, d, f, e, seed=10)
        y_ref = L.moe_ffn(x, gate_w, deq["g"], deq["u"], deq["d"],
                          k, cf, group_size=gs)
        y_fused = L.moe_ffn_fused(x, gate_w, prep["g"], prep["u"],
                                  prep["d"], k, cf, group_size=gs)
        assert y_fused.shape == y_ref.shape
        assert rel_err(y_fused, y_ref) < 0.06

    def test_capacity_overflow_drops_identically(self):
        """Forced overflow (cf = 0.5): dropped tokens produce exact-zero
        rows in BOTH paths, and the dropped sets are identical — routing
        is bit-identical by construction (shared `moe_route`)."""
        x, gate_w, prep, deq = self._setup(2, 32, 32, 64, 4, seed=20)
        y_ref = L.moe_ffn(x, gate_w, deq["g"], deq["u"], deq["d"],
                          2, 0.5, group_size=16)
        y_fused = L.moe_ffn_fused(x, gate_w, prep["g"], prep["u"],
                                  prep["d"], 2, 0.5, group_size=16)
        zero_ref = np.all(np.asarray(y_ref) == 0.0, axis=-1)
        zero_fused = np.all(np.asarray(y_fused) == 0.0, axis=-1)
        assert zero_ref.sum() > 0, "workload never overflowed capacity"
        np.testing.assert_array_equal(zero_ref, zero_fused)
        kept = ~zero_ref
        assert rel_err(np.asarray(y_fused)[kept],
                       np.asarray(y_ref)[kept]) < 0.06

    def test_num_hi_exceeds_seq(self):
        """The stamped round trip ahead of routing with num_hi >= seq:
        every token re-codes at hi_bits, both paths consume the same hq."""
        x, gate_w, prep, deq = self._setup(1, 24, 32, 64, 4, seed=30)
        st = StampConfig(num_hi_tokens=512)
        hq = stamp_fake_quant(x, st, site=None)
        y_ref = L.moe_ffn(hq, gate_w, deq["g"], deq["u"], deq["d"],
                          2, 1.25, group_size=24)
        y_fused = L.moe_ffn_fused(hq, gate_w, prep["g"], prep["u"],
                                  prep["d"], 2, 1.25, group_size=24)
        assert rel_err(y_fused, y_ref) < 0.06

    def test_route_occupancy_is_contiguous_prefix(self):
        """The kernel's scalar-prefetch contract: occupied capacity slots
        of every (group, expert) bucket form a prefix [0, count)."""
        x = rand((3, 16, 32), seed=40)
        gate_w = rand((32, 4), seed=41)
        valid = jnp.ones((3, 16), jnp.float32)
        combine, dispatch, counts = L.moe_route(x, gate_w, 2, 1.0, valid)
        occupied = np.asarray(dispatch).sum(axis=1) > 0      # (b, E, C)
        slot = np.arange(occupied.shape[-1])[None, None, :]
        np.testing.assert_array_equal(
            occupied, slot < np.asarray(counts)[:, :, None])


class TestFusedMoEWiring:
    """End-to-end: fused MoE prefill issues ZERO reference expert einsums
    and exactly one grouped-kernel call per traced MoE layer."""

    CFG = lm.ModelConfig(name="moe-count-test", family="moe", num_layers=2,
                         d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                         vocab_size=128, num_experts=4, experts_per_token=2,
                         moe_group_size=32)

    def test_prefill_zero_reference_expert_einsums(self, monkeypatch):
        from repro.kernels import ops as kops
        params = lm.init_params(jax.random.PRNGKey(0), self.CFG)
        stf = StampConfig(num_hi_tokens=8, execution="fused")
        pf = lm.prepare_fused_weights(params, stf)
        counts = {"grouped": 0}
        real = kops.stamp_quant_grouped_matmul

        def grouped(*a, **k):
            counts["grouped"] += 1
            return real(*a, **k)

        def boom(*a, **k):
            raise AssertionError("reference moe_ffn expert einsums ran")

        monkeypatch.setattr(kops, "stamp_quant_grouped_matmul", grouped)
        monkeypatch.setattr(L, "moe_ffn", boom)
        toks = jnp.asarray(
            np.random.default_rng(1).integers(0, 128, (1, 48)), jnp.int32)
        logits, _ = lm.prefill(params=pf, batch={"tokens": toks},
                               cfg=self.CFG,
                               serve=lm.ServeConfig(
                                   stamp=stf,
                                   kv=KV.KVCacheConfig(quantized=True,
                                                       num_hi=16),
                                   cache_capacity=64))
        assert bool(jnp.isfinite(logits).all())
        # the scanned period traces the layer body once: one grouped call
        assert counts["grouped"] == 1

    def test_prepare_fused_weights_stacks_experts(self):
        params = lm.init_params(jax.random.PRNGKey(0), self.CFG)
        stf = StampConfig(num_hi_tokens=8, execution="fused")
        pf = lm.prepare_fused_weights(params, stf)
        layer = jax.tree.map(lambda a: a, pf["period"][0])
        # stacked (nper, E, din, dout): the whole scanned period prepares
        # in one prepare_linear pass and slices per layer under lax.scan
        for key, (din, dout) in (("we_gate", (64, 128)),
                                 ("we_up", (64, 128)),
                                 ("we_down", (128, 64))):
            w = layer[key]
            assert set(w) == {"iq", "isw", "izw"}
            assert w["iq"].shape == (2, 4, din, dout)
            assert w["iq"].dtype == jnp.int8
            assert w["isw"].shape == (2, 4, 1, dout)

    def test_eligibility_matrix_moe_fused(self):
        stf = StampConfig(num_hi_tokens=8, execution="fused")
        m = lm.fused_site_matrix(self.CFG, stf)
        assert m["moe"]["status"] == "fused"
        assert m["moe"]["kernel"] == "stamp_quant_grouped_matmul"
        assert m["moe"]["reasons"] == []
        # disabled stamp still demotes the cell with a reason (EL001)
        m_off = lm.fused_site_matrix(self.CFG, None)
        assert m_off["moe"]["status"] == "reference"
        assert m_off["moe"]["reasons"] == ["stamp_disabled"]


class TestRouterTelemetry:
    def test_moe_route_records_pseudo_site(self):
        x = rand((2, 16, 32), seed=50)
        gate_w = rand((32, 4), seed=51)
        valid = jnp.ones((2, 16), jnp.float32)
        QS.begin()
        try:
            _, _, counts = L.moe_route(x, gate_w, 2, 0.75, valid)
            raw = QS.end()
        finally:
            if QS.active():
                QS.end()
        assert "moe_router" in raw
        r = raw["moe_router"]
        assert r["expert_tokens"].shape == (4,)
        np.testing.assert_allclose(np.asarray(r["expert_tokens"]).sum(),
                                   np.asarray(counts).sum())
        assert float(r["dropped_tokens"]) >= 0.0
        # summarize passes vector leaves through instead of crashing
        summ = QS.summarize({"moe_router": r})
        assert len(summ["moe_router"]["expert_tokens"]) == 4

    def test_absorb_reduces_stacked_router_stats(self):
        """Scan ride-along: period-stacked router stats sum over the layer
        axis like any quant counter (key-driven reduction)."""
        stacked = {"moe_router": {
            "expert_tokens": jnp.asarray([[1., 2.], [3., 4.]]),
            "dropped_tokens": jnp.asarray([1., 2.]),
            "capacity_slots": jnp.asarray([8., 8.]),
        }}
        QS.begin()
        try:
            QS.absorb(stacked)
            out = QS.end()
        finally:
            if QS.active():
                QS.end()
        np.testing.assert_allclose(
            np.asarray(out["moe_router"]["expert_tokens"]), [4., 6.])
        assert float(out["moe_router"]["dropped_tokens"]) == 3.0

    def test_engine_publishes_router_gauges(self):
        from repro.obs.metrics import MetricsRegistry
        from repro.serving.engine import _EngineBase

        class Stub:
            metrics = MetricsRegistry()

        stub = Stub()
        _EngineBase._absorb_router_stats(stub, {
            "expert_tokens": np.asarray([6.0, 2.0]),
            "dropped_tokens": np.asarray(2.0),
            "capacity_slots": np.asarray(16.0),
        })
        g0 = stub.metrics.gauge("moe_expert_tokens", labels={"expert": "0"})
        assert g0.value == 6.0
        assert stub.metrics.counter("moe_dropped_tokens").value == 2.0
        assert stub.metrics.gauge("moe_capacity_occupancy").value == 0.5
        np.testing.assert_allclose(
            stub.metrics.gauge("moe_drop_rate").value, 0.2)


class TestExpertParallelSharding:
    """Prepared int8 expert buffers shard expert-parallel over 'model'
    through the same suffix-strip rules as the raw weights."""

    POL = ShardingPolicy(mesh=None)

    def test_prepared_expert_codes(self):
        # stacked period leaf: (nper, E, d, f)
        assert self.POL.param_spec("period/we_gate/iq", 4) == \
            P(None, "model", "data", None)
        assert self.POL.param_spec("period/we_down/iq", 4) == \
            P(None, "model", None, "data")

    def test_prepared_expert_scales(self):
        # (nper, E, 1, dout): expert axis stays on 'model'; the scale
        # keeps only the parent's output-dim sharding
        assert self.POL.param_spec("period/we_gate/isw", 4) == \
            P(None, "model", None, None)
        assert self.POL.param_spec("period/we_down/izw", 4) == \
            P(None, "model", None, "data")


class TestChunkAttentionSingleBranch:
    """Satellite regression: the XLA prefill fallback must run ONE
    chunked call per step — no flash variant evaluated alongside and
    discarded by a `jnp.where` (the double-FLOP bug)."""

    def test_no_flash_dispatch_during_paged_prefill(self, monkeypatch):
        from repro.serving.engine import PagedEngineConfig, \
            PagedServingEngine
        calls = {"flash": 0, "chunked": 0}
        real_flash = L.flash_attention
        real_chunked = L.chunked_prefill_attention

        def flash(*a, **k):
            calls["flash"] += 1
            return real_flash(*a, **k)

        def chunked(*a, **k):
            calls["chunked"] += 1
            return real_chunked(*a, **k)

        monkeypatch.setattr(L, "flash_attention", flash)
        monkeypatch.setattr(L, "chunked_prefill_attention", chunked)
        # unique shapes so the engine traces fresh programs in this test
        cfg = lm.ModelConfig(name="attn-branch-test", family="dense",
                             num_layers=2, d_model=96, num_heads=6,
                             num_kv_heads=3, d_ff=160, vocab_size=96)
        params = lm.init_params(jax.random.PRNGKey(7), cfg)
        rng = np.random.default_rng(8)
        for mode in ("unified", "two_call"):
            eng = PagedServingEngine(
                params, cfg,
                lm.ServeConfig(stamp=None,
                               kv=KV.KVCacheConfig(quantized=True,
                                                   num_hi=16)),
                PagedEngineConfig(max_slots=2, prefill_chunk=16,
                                  max_seq=64, block_size=16,
                                  step_mode=mode))
            for n in (30, 17):
                eng.submit(rng.integers(0, 96, n), max_new_tokens=4)
            eng.run()
        assert calls["chunked"] > 0, "prefill never traced chunk attention"
        assert calls["flash"] == 0, \
            "prefill fallback still evaluates the flash branch"

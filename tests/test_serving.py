"""Serving-layer tests: quantized KV-cache properties (hypothesis),
prefill/decode write equivalence, segment-attention equivalence, engine
scheduling, and elastic checkpoint restore onto a different mesh."""

import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

jax.config.update("jax_platform_name", "cpu")

from repro.serving import kvcache as KV
from repro.models.layers import decode_attention, decode_attention_segments

ROOT = pathlib.Path(__file__).resolve().parents[1]


def rand_kv(b, s, g, hd, seed=0):
    rng = np.random.default_rng(seed)
    k = jnp.asarray(rng.normal(size=(b, s, g, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, g, hd)).astype(np.float32))
    return k, v


class TestKVCacheProperties:
    @settings(deadline=None, max_examples=20)
    @given(seed=st.integers(0, 1000), num_hi=st.sampled_from([0, 8, 32]),
           s=st.sampled_from([32, 64, 96]))
    def test_roundtrip_error_bounded(self, seed, num_hi, s):
        """Dequant(quant(K)) error ≤ half a quantization step per region."""
        cfg = KV.KVCacheConfig(quantized=True, num_hi=num_hi)
        k, v = rand_kv(1, s, 2, 16, seed)
        entry = KV.quantize_full(k, v, cfg)
        kd, vd = KV.dequantize_full(entry, cfg, jnp.float32)
        hi = min(num_hi, s)
        for orig, deq in ((k, kd), (v, vd)):
            step_hi = (orig[:, :hi].max(-1) - orig[:, :hi].min(-1)) / 255.0
            step_lo = (orig[:, hi:].max(-1) - orig[:, hi:].min(-1)) / 15.0
            if hi:
                assert float((jnp.abs(deq - orig)[:, :hi].max(-1) -
                              step_hi).max()) < 1e-2
            if s > hi:
                assert float((jnp.abs(deq - orig)[:, hi:].max(-1) -
                              step_lo).max()) < 1e-2

    def test_hi_region_is_8bit_accurate(self):
        cfg = KV.KVCacheConfig(quantized=True, num_hi=16)
        k, v = rand_kv(2, 64, 2, 32, 1)
        entry = KV.quantize_full(k, v, cfg)
        kd, _ = KV.dequantize_full(entry, cfg, jnp.float32)
        err_hi = float(jnp.abs(kd[:, :16] - k[:, :16]).mean())
        err_lo = float(jnp.abs(kd[:, 16:] - k[:, 16:]).mean())
        assert err_hi < err_lo / 4   # 8-bit ≈ 16× finer than 4-bit

    @settings(deadline=None, max_examples=15)
    @given(pos=st.integers(0, 63))
    def test_write_token_matches_bulk_quantization(self, pos):
        """Writing token `pos` incrementally == quantizing it in bulk."""
        cfg = KV.KVCacheConfig(quantized=True, num_hi=16)
        k, v = rand_kv(1, 64, 2, 16, 2)
        bulk = KV.quantize_full(k, v, cfg)
        # start from bulk, overwrite position `pos` with the same values
        rewritten = KV.write_token(bulk, k[:, pos:pos + 1], v[:, pos:pos + 1],
                                   jnp.int32(pos), cfg)
        for key in bulk:
            np.testing.assert_array_equal(
                np.asarray(bulk[key]), np.asarray(rewritten[key]),
                err_msg=f"{key} changed when rewriting identical token")

    def test_write_token_only_touches_position(self):
        cfg = KV.KVCacheConfig(quantized=True, num_hi=16)
        k, v = rand_kv(1, 64, 2, 16, 3)
        entry = KV.quantize_full(k, v, cfg)
        k2, v2 = rand_kv(1, 1, 2, 16, 4)
        new = KV.write_token(entry, k2, v2, jnp.int32(40), cfg)
        kd_old, _ = KV.dequantize_full(entry, cfg, jnp.float32)
        kd_new, _ = KV.dequantize_full(new, cfg, jnp.float32)
        diff = np.abs(np.asarray(kd_old) - np.asarray(kd_new)).sum(axis=(0, 2, 3))
        assert diff[40] > 0
        assert (diff[:40] == 0).all() and (diff[41:] == 0).all()

    def test_effective_bits(self):
        """64×8b + rest×4b ≈ 4.008 bits at 32k (paper: 4.125 at 2k)."""
        cfg = KV.KVCacheConfig(quantized=True, num_hi=64)
        s = 32768
        bits = (64 * 8 + (s - 64) * 4) / s
        assert abs(bits - 4.0078) < 1e-3
        s2 = 2048
        bits2 = (64 * 8 + (s2 - 64) * 4) / s2
        assert abs(bits2 - 4.125) < 1e-3

    def test_capacity_padding_roundtrip(self):
        cfg = KV.KVCacheConfig(quantized=True, num_hi=16)
        k, v = rand_kv(1, 48, 2, 16, 5)
        entry = KV.quantize_full(k, v, cfg, capacity=80)
        assert entry["k_scale"].shape[1] == 80
        kd, _ = KV.dequantize_full(entry, cfg, jnp.float32)
        assert kd.shape[1] == 80
        np.testing.assert_allclose(np.asarray(kd[:, :48]), np.asarray(k),
                                   atol=0.5)


class TestSegmentAttention:
    def test_segments_equal_monolithic(self):
        """Score-merge over (hi, lo) segments == attention over the concat."""
        rng = np.random.default_rng(6)
        b, s, g, hd, h = 2, 96, 2, 32, 8
        k = jnp.asarray(rng.normal(size=(b, s, g, hd)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(b, s, g, hd)).astype(np.float32))
        q = jnp.asarray(rng.normal(size=(b, 1, h, hd)).astype(np.float32))
        length = jnp.asarray([80], jnp.int32)
        whole = decode_attention(q, k, v, length=length)
        split = decode_attention_segments(
            q, [(k[:, :32], v[:, :32], 0), (k[:, 32:], v[:, 32:], 32)],
            length=length)
        np.testing.assert_allclose(np.asarray(split), np.asarray(whole),
                                   atol=2e-2, rtol=2e-2)


class TestElasticRestore:
    @pytest.mark.slow
    def test_restore_on_different_mesh(self, tmp_path):
        """Train on a 1-device mesh, restart on a forced 4-device mesh —
        parameters re-shard at load (elastic scaling)."""
        env = dict(os.environ, PYTHONPATH=str(ROOT / "src"))
        base = [sys.executable, "-m", "repro.launch.train",
                "--arch", "minicpm-2b", "--reduced", "--steps", "8",
                "--global-batch", "4", "--seq", "64", "--ckpt-every", "4",
                "--ckpt-dir", str(tmp_path)]
        p = subprocess.run(base[:6] + ["--steps", "4"] + base[8:], env=env,
                           capture_output=True, text=True, timeout=600)
        assert p.returncode == 0, p.stderr[-500:]
        env4 = dict(env, XLA_FLAGS="--xla_force_host_platform_device_count=4")
        p2 = subprocess.run(base + ["--model-parallel", "2"], env=env4,
                            capture_output=True, text=True, timeout=600)
        assert p2.returncode == 0, p2.stderr[-800:]
        assert "[restore] resumed from step 4" in p2.stdout


class TestFusedKernelIntegration:
    def test_fused_decode_matches_xla_path(self):
        """ServeConfig.fused_cache_attention routes decode through the
        Pallas packed-cache kernel; logits match the XLA segment path."""
        from repro.configs import get_reduced
        from repro.models import lm
        cfg = get_reduced("llama3_8b")
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(0)
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 64)),
                           jnp.int32)
        base = lm.ServeConfig(stamp=None,
                              kv=KV.KVCacheConfig(quantized=True, num_hi=16),
                              weight_bits=None, cache_capacity=96)
        fused = lm.ServeConfig(stamp=None,
                               kv=KV.KVCacheConfig(quantized=True, num_hi=16),
                               weight_bits=None, cache_capacity=96,
                               fused_cache_attention=True)
        _, cache = lm.prefill(params, {"tokens": toks}, cfg, base)
        tok = jnp.zeros((2,), jnp.int32)
        l1, _ = lm.decode_step(params, cache, tok, jnp.int32(64), cfg, base)
        # interpret-mode pallas runs fine under jit; jax.disable_jit() must
        # NOT be used here — pallas_call's interpret impl jits internally and
        # recurses without bound when jit is disabled.
        l2, _ = lm.decode_step(params, cache, tok, jnp.int32(64), cfg, fused)
        lm.set_fused_cache_attention(False)
        rel = np.abs(np.asarray(l1) - np.asarray(l2)).max() / \
            (np.abs(np.asarray(l1)).max() + 1e-9)
        assert rel < 2e-2, rel

    def test_scales_are_f16(self):
        cfg = KV.KVCacheConfig(quantized=True, num_hi=8)
        k, v = rand_kv(1, 32, 2, 16, 9)
        entry = KV.quantize_full(k, v, cfg)
        assert entry["k_scale"].dtype == jnp.float16
        assert entry["v_zp"].dtype == jnp.float16


class TestPackUnpackEdgeCases:
    """Round-trip coverage the paged cache relies on: odd sequence lengths,
    num_hi ≥ seq, and f16 scale/zp exactness at the int8 boundary."""

    @pytest.mark.parametrize("s", [1, 7, 17, 33, 63])
    def test_odd_sequence_lengths_roundtrip(self, s):
        """Sequence lengths that are not multiples of anything: the hi/lo
        split and nibble packing are token-local, so every length packs and
        unpacks within half a quantization step."""
        cfg = KV.KVCacheConfig(quantized=True, num_hi=8)
        k, v = rand_kv(2, s, 2, 16, seed=100 + s)
        entry = KV.quantize_full(k, v, cfg)
        hi = min(cfg.num_hi, s)
        assert entry["k_hi"].shape[1] == hi
        assert entry["k_lo"].shape[1] == s - hi
        kd, vd = KV.dequantize_full(entry, cfg, jnp.float32)
        assert kd.shape == k.shape and vd.shape == v.shape
        for orig, deq in ((k, kd), (v, vd)):
            rng_span = np.asarray(orig.max(-1) - orig.min(-1))
            step = np.where(np.arange(s)[None, :, None] < hi,
                            rng_span / 255.0, rng_span / 15.0)
            err = np.abs(np.asarray(deq - orig)).max(-1)
            # half a step of round-to-nearest plus the f16 scale storage:
            # |q − zp| ≤ 255 and Δscale ≤ scale·2⁻¹¹ adds ≤ 0.125·step
            assert (err <= step * 0.63 + 1e-5).all()

    @pytest.mark.parametrize("s,num_hi", [(4, 8), (16, 16), (8, 64)])
    def test_num_hi_at_least_seq_all_tokens_hi(self, s, num_hi):
        """num_hi ≥ seq: the lo region is empty and every token carries
        8-bit codes; dequant must still round-trip."""
        cfg = KV.KVCacheConfig(quantized=True, num_hi=num_hi)
        k, v = rand_kv(1, s, 2, 16, seed=200 + s)
        entry = KV.quantize_full(k, v, cfg)
        assert entry["k_hi"].shape[1] == s
        assert entry["k_lo"].shape[1] == 0
        kd, _ = KV.dequantize_full(entry, cfg, jnp.float32)
        step = np.asarray(k.max(-1) - k.min(-1)) / 255.0
        # 0.5·step rounding + ≤0.125·step from the f16-stored scale
        assert (np.abs(np.asarray(kd - k)).max(-1) <= step * 0.63 + 1e-5).all()
        # decode write at every position stays in the hi region
        k1, v1 = rand_kv(1, 1, 2, 16, seed=300 + s)
        new = KV.write_token(entry, k1, v1, jnp.int32(s - 1), cfg)
        kd2, _ = KV.dequantize_full(new, cfg, jnp.float32)
        np.testing.assert_allclose(np.asarray(kd2[:, s - 1]),
                                   np.asarray(k1[:, 0]), atol=0.05)

    def test_f16_scale_zp_exact_at_int8_boundary(self):
        """The boundary case the f16 metadata depends on: zp = 255 (an
        all-negative channel) and zp = 0 are integers ≤ 255, hence exact in
        f16 — the f16-stored params must dequantize identically to f32
        params."""
        rng = np.random.default_rng(9)
        base = rng.uniform(0.5, 1.5, size=(1, 16, 2, 16)).astype(np.float32)
        for sign in (-1.0, 1.0):         # zp pinned to 255 / 0
            # anchor the range at zero from one side: max exactly 0 gives
            # zp = 255 (the int8 boundary), min exactly 0 gives zp = 0
            if sign < 0:
                t = jnp.asarray(base - base.max(-1, keepdims=True))
            else:
                t = jnp.asarray(base - base.min(-1, keepdims=True))
            q, scale, zp = KV.quant_tokens(t, 8)
            zp_f16 = zp.astype(jnp.float16)
            scale_f16 = scale.astype(jnp.float16)
            # zero points are exact integers in f16
            np.testing.assert_array_equal(np.asarray(zp_f16, np.float32),
                                          np.asarray(zp))
            expected = 255.0 if sign < 0 else 0.0
            assert float(jnp.abs(zp - expected).max()) == 0.0
            # codes at the extremes (0 and 255) survive the signed shift
            q8, zp_s = KV.to_signed8(q, zp)
            assert int(q8.min()) >= -128 and int(q8.max()) <= 127
            d32 = KV.dequant_tokens(q8.astype(jnp.float32), scale, zp_s,
                                    jnp.float32)
            d16 = KV.dequant_tokens(q8.astype(jnp.float32),
                                    scale_f16.astype(jnp.float32),
                                    (zp_s).astype(jnp.float16)
                                    .astype(jnp.float32), jnp.float32)
            # f16 scale rounding is the only difference: bounded by the
            # f16 epsilon of the scale, no systematic zero-point error
            denom = np.maximum(np.abs(np.asarray(d32)), 1e-6)
            assert (np.abs(np.asarray(d16 - d32)) / denom).max() < 2e-3

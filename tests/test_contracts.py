"""Static program contract checker (`repro.analysis.contracts`).

Covers the four passes with deliberately-broken fixtures — each seeded
violation must surface as its pinned finding code — plus golden
eligibility matrices, ratchet semantics end-to-end through the CLI, the
``python -O`` regression for the converted library asserts, and a
matrix-vs-execution cross-check against the fused kernel call counters.
"""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.contracts import ast_lint, eligibility, jaxpr_lint, \
    kernel_contracts, ratchet
from repro.analysis.contracts.findings import CODES, Finding, assign_keys
from repro.kernels import specs as KS

GOLDEN = os.path.join(os.path.dirname(__file__), "golden")
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _codes(findings):
    return [f.code for f in findings]


def _buf(shape, block, index_map, dtype=np.float32):
    return KS.BufferSpec(shape=shape, dtype=dtype, block_shape=block,
                         index_map=index_map)


def _capture(inputs, outputs, grid, scratch=(), prefetch=()):
    return KS.KernelCapture(name="fixture", grid=grid, inputs=list(inputs),
                            outputs=list(outputs), scratch=list(scratch),
                            num_scalar_prefetch=len(prefetch),
                            prefetch=tuple(prefetch), interpret=True)


class TestSeededKernelViolations:
    """Pass 1 fixtures: each broken capture yields its pinned code."""

    def test_oob_index_map_caught(self):
        # grid runs to 4 but the operand only has 3 rows: classic
        # off-by-one a missing clamp would produce
        cap = _capture(
            inputs=[_buf((3, 8), (1, 8), lambda i: (i, 0))],
            outputs=[_buf((4, 8), (1, 8), lambda i: (i, 0))],
            grid=(4,))
        out = kernel_contracts.check_capture(cap)
        assert "KC001" in _codes(out)

    def test_bad_prefetch_table_caught(self):
        # the block table points one page past the pool — the null-page
        # clamp idiom exists to make this impossible
        table = np.array([0, 1, 4], np.int32)          # pool has 4 pages
        cap = _capture(
            inputs=[_buf((4, 8, 16), (1, 8, 16),
                         lambda i, t: (t[i], 0, 0))],
            outputs=[_buf((3, 8, 16), (1, 8, 16), lambda i, t: (i, 0, 0))],
            grid=(3,), prefetch=(table,))
        out = kernel_contracts.check_capture(cap)
        assert "KC001" in _codes(out)

    def test_vmem_over_budget_caught(self):
        cap = _capture(
            inputs=[_buf((128, 128), (128, 128), lambda i: (0, 0))],
            outputs=[_buf((128, 128), (128, 128), lambda i: (0, 0))],
            grid=(1,), scratch=[((128, 128), np.float32)])
        out = kernel_contracts.check_capture(cap, vmem_budget=64 * 1024)
        assert "KC002" in _codes(out)

    def test_divisibility_caught(self):
        cap = _capture(
            inputs=[_buf((8, 8), (3, 8), lambda i: (i, 0))],
            outputs=[_buf((8, 8), (8, 8), lambda i: (0, 0))],
            grid=(1,))
        out = kernel_contracts.check_capture(cap)
        assert "KC003" in _codes(out)

    def test_f16_accumulator_caught(self):
        def bad(a, b):
            return jax.lax.dot_general(
                a, b, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float16)

        out = []
        kernel_contracts.check_accumulators(
            bad, (jnp.zeros((4, 4), jnp.float16),
                  jnp.zeros((4, 4), jnp.float16)), {}, "fixture.f16", out)
        assert "KC004" in _codes(out)

    def test_int8_dot_without_int32_caught(self):
        def bad(a, b):
            return jax.lax.dot_general(
                a, b, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)

        out = []
        kernel_contracts.check_accumulators(
            bad, (jnp.zeros((4, 4), jnp.int8),
                  jnp.zeros((4, 4), jnp.int8)), {}, "fixture.int8", out)
        assert "KC005" in _codes(out)

    def test_shipped_kernels_are_clean(self):
        """Acceptance: zero findings over the whole capture registry at
        default block sizes and the default VMEM budget."""
        out = kernel_contracts.check_kernels()
        assert out == [], [f"{f.code} {f.scope}: {f.message}" for f in out]


class TestSeededAstViolations:
    """Pass 4 fixtures run through ``lint_source`` directly."""

    def test_bare_assert_caught(self):
        src = textwrap.dedent("""
            def free(self, block):
                assert block in self.used
                self.used.remove(block)
        """)
        out = ast_lint.lint_source(src, "src/repro/fixture.py")
        assert _codes(out) == ["RR001"]
        assert out[0].scope == "free"

    def test_mutable_dataclass_default_caught(self):
        src = textwrap.dedent("""
            from dataclasses import dataclass

            @dataclass
            class Cfg:
                layers: list = []
                names: dict = dict()
        """)
        out = ast_lint.lint_source(src, "src/repro/fixture.py")
        assert _codes(out) == ["RR002", "RR002"]

    def test_interpret_true_default_caught(self):
        src = "def run(x, interpret=True):\n    return x\n"
        out = ast_lint.lint_source(src, "src/repro/fixture.py")
        assert _codes(out) == ["RR003"]

    def test_interpret_none_default_clean(self):
        src = "def run(x, interpret=None):\n    return x\n"
        assert ast_lint.lint_source(src, "src/repro/fixture.py") == []

    def test_time_time_caught(self):
        src = "import time\n\ndef step():\n    return time.time()\n"
        out = ast_lint.lint_source(src, "src/repro/fixture.py")
        assert _codes(out) == ["RR004"]


class TestSeededJaxprViolations:
    """Pass 3 rules on synthetic traced programs."""

    def test_f16_dot_caught(self):
        closed = jax.make_jaxpr(
            lambda a, b: jax.lax.dot_general(
                a, b, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float16))(
            jnp.zeros((4, 4), jnp.float16), jnp.zeros((4, 4), jnp.float16))
        assert "JX002" in _codes(jaxpr_lint.lint_jaxpr(closed, "fixture"))

    def test_convert_round_trip_caught(self):
        closed = jax.make_jaxpr(
            lambda x: x.astype(jnp.bfloat16).astype(jnp.float32))(
            jnp.zeros((8,), jnp.float32))
        out = jaxpr_lint.lint_jaxpr(closed, "fixture")
        assert "JX003" in _codes(out)

    def test_widening_round_trip_clean(self):
        # f32 -> f64-wide is impossible without x64; bf16 -> f32 -> bf16
        # widens in transit and must NOT fire
        closed = jax.make_jaxpr(
            lambda x: x.astype(jnp.float32).astype(jnp.bfloat16))(
            jnp.zeros((8,), jnp.bfloat16))
        assert jaxpr_lint.lint_jaxpr(closed, "fixture") == []

    def test_host_callback_caught(self):
        closed = jax.make_jaxpr(
            lambda x: jax.pure_callback(
                lambda v: v, jax.ShapeDtypeStruct((8,), jnp.float32), x))(
            jnp.zeros((8,), jnp.float32))
        assert "JX004" in _codes(jaxpr_lint.lint_jaxpr(closed, "fixture"))

    def test_f64_caught(self):
        with jax.experimental.enable_x64():
            closed = jax.make_jaxpr(lambda x: x.astype(jnp.float64))(
                jnp.zeros((8,), jnp.float32))
        assert "JX001" in _codes(jaxpr_lint.lint_jaxpr(closed, "fixture"))


class TestEligibility:
    """Pass 2: golden matrices + the completeness invariant."""

    @pytest.mark.parametrize("name", ["llama3_8b", "jamba_1_5_large_398b"])
    def test_golden_matrix(self, name):
        with open(os.path.join(GOLDEN, f"eligibility_{name}.json")) as f:
            golden = json.load(f)
        got = json.loads(json.dumps(eligibility.audit_config(name)))
        assert got == golden

    def test_every_reference_cell_explained(self):
        assert eligibility.check_eligibility() == []

    def test_unexplained_reference_cell_is_el001(self):
        matrix = {"cfg": {"qkv": {"status": "reference", "kernel": None,
                                  "wiring": "merged_wqkv", "layers": 4,
                                  "reasons": []}}}
        # check_eligibility audits real configs; the invariant itself is
        # what the fixture exercises, via the same cell walk
        out = []
        for cfg_name, sites in matrix.items():
            for site, cell in sites.items():
                if cell["status"] == "reference" and not cell["reasons"]:
                    out.append(Finding("EL001", f"configs/{cfg_name}", site,
                                       "unexplained reference cell"))
        assert _codes(out) == ["EL001"]

    def test_disabled_stamp_is_all_reference_with_reasons(self):
        from repro.core.stamp import StampConfig
        m = eligibility.audit_config(
            "llama3_8b", stamp=StampConfig(enabled=False))
        assert all(c["status"] == "reference" for c in m.values())
        assert all("stamp_disabled" in c["reasons"] for c in m.values())

    def test_matrix_document_schema(self):
        m = eligibility.audit_all(["llama3_8b"])
        doc = eligibility.matrix_document(m)
        assert doc["version"] == 1
        assert doc["stamp"]["execution"] == "fused"
        assert set(doc["configs"]) == {"llama3_8b"}


class TestMatrixMatchesExecution:
    """Cross-check: the audited matrix agrees with the kernels the fused
    prefill actually dispatches (same counter idiom as
    test_stamp_fused.TestNoReferenceRoundTrips)."""

    def _counted(self, monkeypatch):
        from repro.kernels import ops as kops
        counts = {"single": 0, "dual": 0}
        real_single, real_dual = (kops.stamp_quant_matmul,
                                  kops.stamp_quant_dual_matmul)

        def single(*a, **k):
            counts["single"] += 1
            return real_single(*a, **k)

        def dual(*a, **k):
            counts["dual"] += 1
            return real_dual(*a, **k)

        monkeypatch.setattr(kops, "stamp_quant_matmul", single)
        monkeypatch.setattr(kops, "stamp_quant_dual_matmul", dual)
        return counts

    def test_dense_layer_matrix_matches_counters(self, monkeypatch):
        from repro.core.stamp import StampConfig
        from repro.models import lm
        from repro.models.config import ModelConfig
        from repro.serving import kvcache as KV
        cfg = ModelConfig(name="xcheck", family="dense", num_layers=2,
                          d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                          vocab_size=128, qkv_bias=True)
        stamp = StampConfig(num_hi_tokens=8, execution="fused")
        matrix = lm.fused_site_matrix(cfg, stamp)
        n_single = sum(1 for c in matrix.values()
                       if c["kernel"] == "stamp_quant_matmul")
        n_dual = sum(1 for c in matrix.values()
                     if c["kernel"] == "stamp_quant_dual_matmul")
        assert all(c["status"] == "fused" for c in matrix.values())

        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        pf = lm.prepare_fused_weights(params, stamp)
        counts = self._counted(monkeypatch)
        toks = jnp.asarray(
            np.random.default_rng(1).integers(0, 128, (1, 64)), jnp.int32)
        logits, _ = lm.prefill(
            params=pf, batch={"tokens": toks}, cfg=cfg,
            serve=lm.ServeConfig(stamp=stamp,
                                 kv=KV.KVCacheConfig(quantized=True,
                                                     num_hi=16),
                                 cache_capacity=96))
        assert bool(jnp.isfinite(logits).all())
        # the scanned period traces each fused site exactly once
        assert counts["single"] == n_single
        assert counts["dual"] == n_dual

    def test_reference_matrix_means_no_fused_calls(self, monkeypatch):
        from repro.models import lm
        from repro.models.config import ModelConfig
        from repro.serving import kvcache as KV
        cfg = ModelConfig(name="xcheck-ref", family="dense", num_layers=2,
                          d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                          vocab_size=128)
        matrix = lm.fused_site_matrix(cfg, None)
        assert all(c["status"] == "reference" for c in matrix.values())
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        counts = self._counted(monkeypatch)
        toks = jnp.zeros((1, 32), jnp.int32)
        lm.prefill(params=params, batch={"tokens": toks}, cfg=cfg,
                   serve=lm.ServeConfig(
                       stamp=None, kv=KV.KVCacheConfig(quantized=True,
                                                       num_hi=16),
                       cache_capacity=64))
        assert counts == {"single": 0, "dual": 0}


class TestRatchet:
    def _findings(self):
        return [Finding("RR001", "src/repro/a.py", "f", "assert one"),
                Finding("RR001", "src/repro/a.py", "f", "assert two"),
                Finding("RR004", "src/repro/b.py", "g", "time.time")]

    def test_keys_are_stable_and_ordinal(self):
        fs = self._findings()
        assign_keys(fs)
        assert fs[0].key == "RR001:src/repro/a.py:f#0"
        assert fs[1].key == "RR001:src/repro/a.py:f#1"
        assert fs[2].key == "RR004:src/repro/b.py:g#0"

    def test_grandfather_new_stale(self, tmp_path):
        path = str(tmp_path / "baseline.json")
        fs = self._findings()
        ratchet.write_baseline(path, fs, vmem_budget=1)
        baseline = ratchet.load_baseline(path)

        # same findings: all grandfathered
        new, grand, stale = ratchet.ratchet(self._findings(), baseline)
        assert (len(new), len(grand), stale) == (0, 3, [])

        # one extra finding in an allowlisted scope: only IT is new
        more = self._findings() + [
            Finding("RR001", "src/repro/a.py", "f", "assert three")]
        new, grand, stale = ratchet.ratchet(more, baseline)
        assert [f.message for f in new] == ["assert three"]

        # one fixed: its key goes stale, nothing new
        new, grand, stale = ratchet.ratchet(self._findings()[:2], baseline)
        assert new == [] and stale == ["RR004:src/repro/b.py:g#0"]

    def test_version_mismatch_raises(self, tmp_path):
        path = tmp_path / "baseline.json"
        path.write_text('{"version": 99, "allowlist": []}')
        with pytest.raises(ValueError):
            ratchet.load_baseline(str(path))

    def test_missing_baseline_is_none(self, tmp_path):
        assert ratchet.load_baseline(str(tmp_path / "nope.json")) is None


class TestCliRatchetEndToEnd:
    """The gate as CI runs it: seeded repo fails, baseline grandfathers,
    fixing goes stale — all through the module CLI and exit codes."""

    def _run(self, tmp, *extra):
        env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
        return subprocess.run(
            [sys.executable, "-m", "repro.analysis.contracts",
             "--passes", "ast", "--root", str(tmp),
             "--baseline", str(tmp / "STATIC_ANALYSIS.json"), *extra],
            capture_output=True, text=True, env=env, cwd=REPO)

    def test_seed_baseline_fix(self, tmp_path):
        pkg = tmp_path / "src" / "repro"
        pkg.mkdir(parents=True)
        (pkg / "bad.py").write_text(
            "def f(x):\n    assert x\n    return x\n")

        r = self._run(tmp_path)
        assert r.returncode == 1, r.stdout + r.stderr
        assert "RR001:src/repro/bad.py:f#0" in r.stderr

        r = self._run(tmp_path, "--update-baseline")
        assert r.returncode == 0, r.stdout + r.stderr
        doc = json.loads((tmp_path / "STATIC_ANALYSIS.json").read_text())
        assert doc["allowlist"] == ["RR001:src/repro/bad.py:f#0"]

        r = self._run(tmp_path)
        assert r.returncode == 0 and "grandfathered" in r.stdout

        (pkg / "bad.py").write_text("def f(x):\n    return x\n")
        r = self._run(tmp_path)
        assert r.returncode == 0 and "stale" in r.stdout

    def test_committed_baseline_is_green(self):
        """The repo's own STATIC_ANALYSIS.json passes the ast pass (the
        full four-pass run is the CI step; ast is the cheap sentinel)."""
        env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
        r = subprocess.run(
            [sys.executable, "-m", "repro.analysis.contracts",
             "--passes", "ast"],
            capture_output=True, text=True, env=env, cwd=REPO)
        assert r.returncode == 0, r.stdout + r.stderr


class TestPythonOMinusO:
    """Satellite (a) regression: validation that used to be ``assert`` must
    still raise under ``python -O`` (where asserts vanish)."""

    CASES = {
        "wht_pow2": """
            import jax.numpy as jnp
            from repro.kernels.wht import wht_pallas
            try:
                wht_pallas(jnp.zeros((1, 24, 8)), axis=-2, block=8)
            except ValueError:
                print("RAISED")
        """,
        "stamp_bits": """
            import jax.numpy as jnp
            from repro.core.stamp import prepare_linear
            try:
                prepare_linear(jnp.zeros((8, 8)), bits=16)
            except ValueError:
                print("RAISED")
        """,
        "matmul_k": """
            import jax.numpy as jnp
            from repro.kernels.stamp_matmul import stamp_quant_matmul_pallas
            try:
                stamp_quant_matmul_pallas(
                    jnp.zeros((1, 8, 16)), jnp.zeros((24, 8), jnp.int8),
                    jnp.ones((1, 8)), jnp.zeros((1, 8)),
                    jnp.zeros((1, 8)), num_hi=4)
            except ValueError:
                print("RAISED")
        """,
    }

    @pytest.mark.parametrize("name", sorted(CASES))
    def test_valueerror_survives_dash_o(self, name):
        env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
        r = subprocess.run(
            [sys.executable, "-O", "-c", textwrap.dedent(self.CASES[name])],
            capture_output=True, text=True, env=env)
        assert r.returncode == 0, r.stderr
        assert "RAISED" in r.stdout, r.stdout + r.stderr


class TestFindingCodes:
    def test_unknown_code_rejected(self):
        with pytest.raises(ValueError):
            Finding("ZZ999", "p", "s", "m")

    def test_codes_cover_all_passes(self):
        prefixes = {c[:2] for c in CODES}
        assert prefixes == {"KC", "EL", "JX", "RR"}

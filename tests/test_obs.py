"""Observability-layer tests: metrics registry (bucket semantics +
percentiles vs a numpy oracle, Prometheus exposition), structured events
(legacy tuple compat per kind), step-phase timing on a fake tick clock,
Chrome-trace export schema, quantization-health stats against an fp32
numpy oracle (including deliberately clipped injected scales), and the
engine-level contract: one registry-backed ``stats`` surface on BOTH
engines and ZERO extra device dispatches when telemetry is on."""

import json

import jax
import numpy as np
import pytest

jax.config.update("jax_platform_name", "cpu")

import jax.numpy as jnp                                        # noqa: E402

from repro.core import quant as Q                              # noqa: E402
from repro.core.stamp import StampConfig                       # noqa: E402
from repro.models import lm                                    # noqa: E402
from repro.models.config import ModelConfig                    # noqa: E402
from repro.obs import quantstats as QS                         # noqa: E402
from repro.obs.metrics import (LATENCY_BUCKETS, Histogram,     # noqa: E402
                               MetricsRegistry, exponential_buckets)
from repro.obs.trace import Event, StepTimer, export_chrome_trace  # noqa: E402
from repro.serving import kvcache as KV                        # noqa: E402
from repro.serving.engine import (BucketedEngine, EngineConfig,  # noqa: E402
                                  PagedEngineConfig, PagedServingEngine)


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

class TestHistogram:
    def test_bucket_edges_le_semantics(self):
        h = Histogram((1.0, 2.0, 4.0))
        for v in (0.5, 1.0):      # v <= 1.0 -> bucket 0 (le semantics)
            h.observe(v)
        h.observe(1.5)            # bucket 1
        h.observe(4.0)            # exactly the last edge -> bucket 2
        h.observe(9.0)            # overflow
        assert h.counts == [2, 1, 1, 1]
        assert h.count == 5
        assert h.sum == pytest.approx(0.5 + 1.0 + 1.5 + 4.0 + 9.0)

    def test_percentile_vs_numpy_oracle(self):
        """Dense geometric buckets: the interpolated estimate must land
        within one bucket width of numpy's exact quantile."""
        rng = np.random.default_rng(7)
        xs = rng.lognormal(mean=-3.0, sigma=1.0, size=4000)
        edges = exponential_buckets(1e-4, 1.15, 80)
        h = Histogram(edges)
        for v in xs:
            h.observe(v)
        for q in (0.5, 0.9, 0.99):
            exact = float(np.quantile(xs, q))
            est = h.percentile(q)
            i = int(np.searchsorted(edges, exact))
            lo = edges[i - 1] if i > 0 else 0.0
            hi = edges[min(i, len(edges) - 1)]
            assert lo * 0.999 <= est <= hi * 1.001, \
                f"q={q}: est {est} outside covering bucket [{lo}, {hi}]"

    def test_percentile_edge_cases(self):
        h = Histogram((1.0, 2.0))
        assert h.percentile(0.5) == 0.0          # empty
        h.observe(100.0)                         # overflow only
        assert h.percentile(0.5) == 2.0          # reports last finite edge
        with pytest.raises(ValueError):
            h.percentile(1.5)

    def test_exponential_buckets(self):
        edges = exponential_buckets(0.5, 2.0, 4)
        assert edges == (0.5, 1.0, 2.0, 4.0)
        with pytest.raises(ValueError):
            exponential_buckets(0.0, 2.0, 4)
        with pytest.raises(ValueError):
            exponential_buckets(0.5, 1.0, 4)

    def test_unsorted_edges_rejected(self):
        with pytest.raises(ValueError):
            Histogram((2.0, 1.0))


class TestRegistry:
    def test_counter_monotonic(self):
        reg = MetricsRegistry()
        c = reg.counter("ops")
        c.inc()
        c.inc(3)
        assert c.value == 4
        with pytest.raises(ValueError):
            c.inc(-1)

    def test_get_or_create_returns_same_child(self):
        reg = MetricsRegistry()
        a = reg.counter("x", labels={"site": "qkv"})
        b = reg.counter("x", labels={"site": "qkv"})
        other = reg.counter("x", labels={"site": "wo"})
        assert a is b and a is not other

    def test_type_conflict_raises(self):
        reg = MetricsRegistry()
        reg.counter("n")
        with pytest.raises(ValueError):
            reg.gauge("n")

    def test_invalid_name_raises(self):
        with pytest.raises(ValueError):
            MetricsRegistry().counter("bad name!")

    def test_reset_excludes(self):
        reg = MetricsRegistry()
        reg.counter("recompiles").inc(5)
        reg.counter("steps").inc(9)
        reg.histogram("ttft_s").observe(0.1)
        reg.reset(exclude=("recompiles",))
        assert reg.counter("recompiles").value == 5
        assert reg.counter("steps").value == 0
        assert reg.histogram("ttft_s").count == 0

    def test_snapshot_and_json(self):
        reg = MetricsRegistry(clock=lambda: 123.0)
        reg.counter("steps").inc(2)
        reg.gauge("load", labels={"k": "waiting"}).set(3)
        reg.histogram("lat", buckets=(1.0, 2.0)).observe(1.5)
        snap = reg.snapshot()
        assert snap["t"] == 123.0
        assert snap["counters"]["steps"] == 2
        assert snap["gauges"]['load{k="waiting"}'] == 3
        hist = snap["histograms"]["lat"]
        assert hist["edges"] == [1.0, 2.0]
        assert hist["counts"] == [0, 1, 0]
        assert hist["count"] == 1
        assert json.loads(reg.to_json()) == json.loads(reg.to_json())

    def test_prometheus_exposition(self):
        reg = MetricsRegistry()
        reg.counter("steps", help="engine steps").inc(2)
        h = reg.histogram("lat", buckets=(1.0, 2.0))
        h.observe(0.5)
        h.observe(1.5)
        h.observe(9.0)
        text = reg.to_prometheus()
        assert "# HELP steps engine steps" in text
        assert "# TYPE steps counter" in text
        assert "steps 2" in text
        # cumulative le buckets + the +Inf bucket equal to count
        assert 'lat_bucket{le="1"} 1' in text
        assert 'lat_bucket{le="2"} 2' in text
        assert 'lat_bucket{le="+Inf"} 3' in text
        assert "lat_sum 11" in text
        assert "lat_count 3" in text


# ---------------------------------------------------------------------------
# events + step timer
# ---------------------------------------------------------------------------

class TestEvent:
    @pytest.mark.parametrize("ev,payload", [
        (Event(3, "prefill_chunk", uid=1, fields={"start": 0, "end": 16}),
         (1, 0, 16)),
        (Event(4, "decode", fields={"uids": (1, 2, 5)}), (1, 2, 5)),
        (Event(5, "demote", fields={"to": "reference"}), "reference"),
        (Event(6, "fault_exhaust"), 6),
        (Event(7, "fail", uid=2, fields={"error": "deadline"}),
         (2, "deadline")),
        (Event(8, "finish", uid=3), 3),
        (Event(9, "admit", uid=4), 4),
    ])
    def test_legacy_payload_shapes(self, ev, payload):
        step, kind, p = ev             # tuple unpacking via __iter__
        assert (step, kind, p) == (ev.step, ev.kind, payload)


class TickClock:
    """Deterministic clock: each read advances by ``tick`` and counts."""

    def __init__(self, tick=1.0):
        self.t = 0.0
        self.tick = tick
        self.reads = 0

    def __call__(self):
        self.reads += 1
        self.t += self.tick
        return self.t


class TestStepTimer:
    def test_exact_phase_timing_two_reads_per_phase(self):
        clk = TickClock(tick=1.0)
        reg = MetricsRegistry()
        slices = []
        timer = StepTimer(reg, clk, on_phase=lambda n, t0, d:
                          slices.append((n, t0, d)))
        with timer.phase("plan"):
            pass
        with timer.phase("dispatch"):
            pass
        assert clk.reads == 4                       # exactly 2 per phase
        assert slices == [("plan", 1.0, 1.0), ("dispatch", 3.0, 1.0)]
        h = reg.histogram("step_phase_s", labels={"phase": "plan"})
        assert h.count == 1 and h.sum == pytest.approx(1.0)

    def test_observes_even_on_exception(self):
        reg = MetricsRegistry()
        timer = StepTimer(reg, TickClock())
        with pytest.raises(RuntimeError):
            with timer.phase("post"):
                raise RuntimeError("boom")
        assert reg.histogram("step_phase_s",
                             labels={"phase": "post"}).count == 1


# ---------------------------------------------------------------------------
# Chrome trace export
# ---------------------------------------------------------------------------

def _lifecycle_events():
    """A hand-built ring: one request through submit -> admit -> chunk ->
    first token -> preempt -> admit -> finish, with step-phase slices."""
    return [
        Event(0, "submit", uid=1, t=0.0, fields={"prompt_len": 20}),
        Event(1, "phase", t=0.5, dur=0.2, phase="plan"),
        Event(1, "admit", uid=1, t=1.0),
        Event(1, "prefill_chunk", uid=1, t=1.0, dur=0.5,
              fields={"start": 0, "end": 16}),
        Event(2, "first_token", uid=1, t=2.0),
        Event(3, "preempt", uid=1, t=3.0),
        Event(4, "admit", uid=1, t=4.0),
        Event(4, "resume", uid=1, t=4.0),
        Event(5, "finish", uid=1, t=5.0),
    ]


class TestChromeTrace:
    def test_schema(self):
        doc = export_chrome_trace(_lifecycle_events(), engine="test")
        assert set(doc) == {"traceEvents", "displayTimeUnit", "metadata"}
        assert doc["metadata"]["engine"] == "test"
        for ev in doc["traceEvents"]:
            assert ev["ph"] in ("M", "X", "i")
            assert {"name", "pid", "tid"} <= set(ev)
            if ev["ph"] != "M":
                assert ev["ts"] >= 0
            if ev["ph"] == "X":
                assert ev["dur"] >= 0
            if ev["ph"] == "i":
                assert ev["s"] == "t"
        assert json.loads(json.dumps(doc)) == doc     # JSON-serializable

    def test_lifecycle_spans(self):
        doc = export_chrome_trace(_lifecycle_events())
        spans = [(e["name"], e["ts"], e["dur"]) for e in doc["traceEvents"]
                 if e["ph"] == "X" and e["tid"] == 2]     # uid 1 -> tid 2
        names = [n for n, _, _ in spans]
        # submit->admit WAITING, admit->first_token PREFILLING, then
        # DECODING, preempt puts it back to WAITING, and after the second
        # admit it resumes DECODING until the terminal
        assert names.count("WAITING") == 2
        assert "PREFILLING" in names
        assert names.count("DECODING") == 2
        assert any(n.startswith("prefill[0:16)") for n in names)
        wait = next(s for s in spans if s[0] == "WAITING")
        assert wait[1] == 0 and wait[2] == 1_000_000      # 0 -> 1s, in µs
        instants = [e["name"] for e in doc["traceEvents"] if e["ph"] == "i"]
        assert "first token" in instants
        assert "terminal: finish" in instants
        assert any("preempt" in n for n in instants)

    def test_phase_slices_on_step_thread(self):
        doc = export_chrome_trace(_lifecycle_events())
        phases = [e for e in doc["traceEvents"]
                  if e["ph"] == "X" and e["tid"] == 0]
        assert [p["name"] for p in phases] == ["plan"]
        assert phases[0]["dur"] == 200_000               # 0.2 s in µs

    def test_empty_ring(self):
        doc = export_chrome_trace([])
        assert doc["traceEvents"] == []

    def test_open_request_closed_at_last_timestamp(self):
        doc = export_chrome_trace([
            Event(0, "submit", uid=1, t=0.0),
            Event(1, "admit", uid=1, t=1.0),
            Event(2, "first_token", uid=1, t=2.0),
        ])
        spans = [e for e in doc["traceEvents"] if e["ph"] == "X"]
        open_span = [e for e in spans if e["args"].get("open")]
        assert len(open_span) == 1 and open_span[0]["name"] == "DECODING"


# ---------------------------------------------------------------------------
# quant-health stats vs fp32 numpy oracle
# ---------------------------------------------------------------------------

class TestSiteStats:
    def _oracle(self, x, bits, scale, zp):
        n = 2.0 ** bits - 1.0
        q = np.round(x / scale) + zp
        clipped = int(np.sum((q < -0.5) | (q > n + 0.5)))
        qc = np.clip(q, 0.0, n)
        saturated = int(np.sum((qc <= 0.5) | (qc >= n - 0.5)))
        return clipped, saturated

    def test_minmax_scales_never_clip(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(2, 16, 32)), jnp.float32)
        out = QS.site_stats(x, bits=4.0, hi_bits=8)
        assert float(out["clipped"]) == 0.0
        assert float(out["elems"]) == x.size
        assert float(out["tokens"]) == 32
        assert float(out["saturated"]) > 0       # min/max always on rails

    def test_clip_rate_vs_oracle_with_tight_scales(self):
        """Inject deliberately narrow quantizer params so real clipping
        occurs, and check the device counts against a numpy oracle."""
        rng = np.random.default_rng(1)
        x = rng.normal(size=(1, 8, 64)).astype(np.float32)
        bits = 4.0
        scale = np.full((1, 8, 1), 0.08, np.float32)   # much too narrow
        zp = np.full((1, 8, 1), 7.0, np.float32)
        clipped, saturated = self._oracle(x, bits, scale, zp)
        assert clipped > 0, "oracle setup must actually clip"
        out = QS.site_stats(jnp.asarray(x), bits, hi_bits=8,
                            scale=jnp.asarray(scale), zp=jnp.asarray(zp))
        assert int(out["clipped"]) == clipped
        assert int(out["saturated"]) == saturated

    def test_hi_token_coverage_with_bits_vector(self):
        x = jnp.ones((2, 8, 16), jnp.float32)
        bits = Q.mixed_precision_bits(8, num_hi=2, hi_bits=8, lo_bits=4)
        out = QS.site_stats(x, bits, hi_bits=8)
        # 2 hi tokens of 8, times 2 batch rows
        assert float(out["hi_tokens"]) == 4.0
        assert float(out["tokens"]) == 16.0
        summ = QS.summarize({"qkv": out})["qkv"]
        assert summ["hi_coverage"] == pytest.approx(0.25)
        assert 0.0 <= summ["clip_rate"] <= 1.0

    def test_collector_scope(self):
        assert not QS.active()
        QS.begin()
        QS.record("qkv", jnp.ones((1, 4, 8)), 4.0, 8)
        QS.record("qkv", jnp.ones((1, 4, 8)), 4.0, 8)
        out = QS.end()
        assert not QS.active()
        assert float(out["qkv"]["tokens"]) == 8.0   # merged, not replaced
        # records outside a scope are dropped, not an error
        QS.record("qkv", jnp.ones((1, 4, 8)), 4.0, 8)
        assert QS.end() == {}


# ---------------------------------------------------------------------------
# engine-level contract
# ---------------------------------------------------------------------------

CFG = ModelConfig(name="obs-test", family="dense", num_layers=2,
                  d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                  vocab_size=128)
QUANT = KV.KVCacheConfig(quantized=True, num_hi=16)
STAMP_SERVE = lm.ServeConfig(stamp=StampConfig(num_hi_tokens=8), kv=QUANT)


@pytest.fixture(scope="module")
def params():
    return lm.init_params(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def prompts():
    rng = np.random.default_rng(3)
    return [rng.integers(0, CFG.vocab_size, l) for l in (20, 33, 12)]


def _paged_cfg(**kw):
    kw.setdefault("max_slots", 3)
    kw.setdefault("prefill_chunk", 16)
    kw.setdefault("max_seq", 96)
    kw.setdefault("block_size", 16)
    return PagedEngineConfig(**kw)


def _run(engine, prompts, max_new=6):
    for p in prompts:
        engine.submit(p, max_new_tokens=max_new)
    return engine.run()


class TestEngineObservability:
    def test_bucketed_engine_has_registry_surface(self, params, prompts):
        """The lockstep engine publishes the SAME stats/events surface as
        the paged engine — the old hasattr special-casing is dead."""
        eng = BucketedEngine(params, CFG, lm.ServeConfig(stamp=None,
                                                         kv=QUANT),
                             EngineConfig(max_batch=4, bucket=64,
                                          max_seq=96))
        done = _run(eng, prompts)
        st = eng.stats
        assert set(st) == set(eng.STAT_KEYS) | {"reference_fallback_sites"}
        assert st["steps"] > 0 and st["device_dispatches"] > 0
        assert st["finished"] == len(done) and st["preemptions"] == 0
        kinds = {k for _, k, _ in eng.events}
        assert {"submit", "admit", "first_token", "finish",
                "phase"} <= kinds
        assert eng.metrics.histogram("ttft_s").count == len(done)
        assert eng.metrics.histogram("latency_s").count == len(done)
        eng.reset_stats(clear_events=True)
        assert eng.stats["finished"] == 0 and len(eng.events) == 0

    def test_paged_trace_round_trip(self, params, prompts):
        """Engine ring -> export_chrome_trace: every finished request has
        a full WAITING/PREFILLING/DECODING timeline and a terminal."""
        eng = PagedServingEngine(params, CFG,
                                 lm.ServeConfig(stamp=None, kv=QUANT),
                                 _paged_cfg())
        done = _run(eng, prompts)
        doc = export_chrome_trace(eng.events, engine="paged")
        spans = [e for e in doc["traceEvents"] if e["ph"] == "X"]
        for r in done:
            tid = r.uid + 1
            names = [e["name"] for e in spans if e["tid"] == tid]
            assert "WAITING" in names and "PREFILLING" in names
            assert "DECODING" in names
        terminals = [e["name"] for e in doc["traceEvents"]
                     if e["ph"] == "i" and e["name"].startswith("terminal")]
        assert len(terminals) == len(done)
        assert {e["name"] for e in spans if e["tid"] == 0} <= \
            {"plan", "dispatch", "post"}

    def test_quant_telemetry_zero_extra_dispatches(self, params, prompts):
        """The telemetry scalars ride in the same device program: token
        output AND dispatch count are identical with telemetry on/off."""
        import dataclasses
        runs = {}
        for on in (False, True):
            serve = dataclasses.replace(STAMP_SERVE, quant_telemetry=on)
            eng = PagedServingEngine(params, CFG, serve, _paged_cfg())
            done = _run(eng, prompts)
            runs[on] = (eng, {r.uid: list(r.out_tokens) for r in done})
        eng_off, toks_off = runs[False]
        eng_on, toks_on = runs[True]
        assert toks_on == toks_off, "telemetry changed the numerics"
        assert eng_on.stats["device_dispatches"] == \
            eng_off.stats["device_dispatches"], \
            "quant telemetry must not add device dispatches"
        snap = eng_on.metrics.snapshot()
        cov = {k: v for k, v in snap["gauges"].items()
               if k.startswith("quant_hi_coverage")}
        assert cov, "no per-site coverage gauges published"
        assert all(0.0 <= v <= 1.0 for v in cov.values())
        clip = {k: v for k, v in snap["gauges"].items()
                if k.startswith("quant_clip_rate")}
        # min-max scales clip nothing by construction
        assert clip and all(v == 0.0 for v in clip.values())
        assert not any(k.startswith("quant_") for k in
                       eng_off.metrics.snapshot()["gauges"])

    def test_clip_alert_fires_below_threshold(self, params, prompts):
        """A negative threshold guarantees every step trips the alert —
        exercises the counter + event path without pathological inputs."""
        import dataclasses
        serve = dataclasses.replace(STAMP_SERVE, quant_telemetry=True)
        eng = PagedServingEngine(params, CFG, serve,
                                 _paged_cfg(clip_alert_threshold=-1.0))
        _run(eng, prompts)
        snap = eng.metrics.snapshot()
        alerts = {k: v for k, v in snap["counters"].items()
                  if k.startswith("quant_clip_alerts")}
        assert alerts and all(v > 0 for v in alerts.values())
        assert any(k == "quant_clip_alert" for _, k, _ in eng.events)

    def test_scheduler_load_gauges(self, params, prompts):
        eng = PagedServingEngine(params, CFG,
                                 lm.ServeConfig(stamp=None, kv=QUANT),
                                 _paged_cfg())
        _run(eng, prompts)
        snap = eng.metrics.snapshot()
        for name in ("sched_waiting", "sched_active", "sched_free_slots",
                     "sched_free_hi_pages", "sched_free_lo_pages"):
            assert name in snap["gauges"]
        # drained engine: nothing waiting or active
        assert snap["gauges"]["sched_waiting"] == 0
        assert snap["gauges"]["sched_active"] == 0

    def test_obs_clock_isolated_from_engine_clock(self, params, prompts):
        """Deadline semantics live on the engine clock; histograms and
        event timestamps on the obs clock.  An injected obs tick-clock
        must not perturb tokens or engine-clock latencies."""
        obs = TickClock(tick=0.25)
        eng = PagedServingEngine(params, CFG,
                                 lm.ServeConfig(stamp=None, kv=QUANT),
                                 _paged_cfg(), obs_clock=obs)
        done = _run(eng, prompts)
        assert obs.reads > 0
        assert eng.metrics.histogram("ttft_s").count == len(done)
        ts = [e.t for e in eng.events]
        assert ts == sorted(ts), "obs timestamps must be monotonic"
        # engine-clock latencies are real perf_counter intervals, not the
        # virtual obs ticks
        assert all(0.0 <= r.latency_s < 60.0 for r in done)

"""Distribution-layer tests: sharding rules, small-mesh lower+compile,
checkpoint/restart (incl. injected crash), elastic re-shard, gradient
compression, data determinism, HLO analyzer correctness."""

import dataclasses
import json
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_platform_name", "cpu")

from jax.sharding import PartitionSpec as P

from repro.analysis import hlo as H
from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, DataIterator, markov_batch
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.optim.compression import (compress_gradients,
                                     error_feedback_update,
                                     init_error_state)
from repro.optim.schedules import wsd_schedule, cosine_schedule

ROOT = pathlib.Path(__file__).resolve().parents[1]


class TestShardingRules:
    def test_param_specs(self):
        from repro.launch.mesh import make_local_mesh
        from repro.sharding import ShardingPolicy
        policy = ShardingPolicy(mesh=make_local_mesh())
        assert policy.param_spec("period/0/wq", 3) == P(None, "data", "model")
        assert policy.param_spec("period/0/wo", 3) == P(None, "model", "data")
        assert policy.param_spec("embed", 2) == P("model", "data")
        assert policy.param_spec("period/0/we_gate", 4) == \
            P(None, "model", "data", None)
        assert policy.param_spec("period/0/ln1", 2) == P(None, None)
        # packed-int4 leaves inherit the parent rule
        assert policy.param_spec("period/0/wq/q", 3) == \
            P(None, "data", "model")
        assert policy.param_spec("period/0/wq/scale", 3) == \
            P(None, None, "model")
        # fused-path prepared int8 leaves inherit it too
        assert policy.param_spec("period/0/wq/iq", 3) == \
            P(None, "data", "model")
        assert policy.param_spec("period/0/wqkv/iq", 3) == \
            P(None, "data", "model")
        assert policy.param_spec("period/0/wqkv/isw", 3) == \
            P(None, None, "model")
        assert policy.param_spec("period/0/wo_mlp/iq", 3) == \
            P(None, "model", "data")
        assert policy.param_spec("period/0/wq/isw", 3) == \
            P(None, None, "model")
        assert policy.param_spec("period/0/wq/izw", 3) == \
            P(None, None, "model")

    def test_seq_sharded_acts(self):
        from repro.launch.mesh import make_local_mesh
        from repro.sharding import ShardingPolicy
        p = ShardingPolicy(mesh=make_local_mesh(), seq_sharded=True)
        assert p.acts() == P(("data",), "model", None)


class TestSchedules:
    def test_wsd_shape(self):
        s = wsd_schedule(1e-3, warmup=10, total=100)
        assert float(s(jnp.asarray(0))) == 0.0
        assert abs(float(s(jnp.asarray(50))) - 1e-3) < 1e-9   # stable
        assert float(s(jnp.asarray(99))) < 2e-4               # decayed

    def test_cosine(self):
        s = cosine_schedule(1e-3, warmup=10, total=100)
        assert float(s(jnp.asarray(100))) < float(s(jnp.asarray(50)))


class TestOptimizer:
    def test_adamw_matches_reference(self):
        cfg = AdamWConfig(lr=1e-2, weight_decay=0.0, grad_clip=1e9)
        params = {"w": jnp.ones((4,)) * 2.0}
        grads = {"w": jnp.ones((4,)) * 0.5}
        state = adamw_init(params, cfg)
        new_p, state, _ = adamw_update(grads, state, params, cfg)
        # step 1: mhat = g, vhat = g², delta = 1 → p - lr
        np.testing.assert_allclose(np.asarray(new_p["w"]),
                                   2.0 - 1e-2 * (0.5 / (0.5 + 1e-8)),
                                   rtol=1e-5)

    def test_grad_clipping(self):
        cfg = AdamWConfig(lr=1e-2, grad_clip=1.0)
        params = {"w": jnp.zeros((100,))}
        grads = {"w": jnp.ones((100,)) * 10.0}  # norm = 100
        state = adamw_init(params, cfg)
        _, _, metrics = adamw_update(grads, state, params, cfg)
        assert float(metrics["grad_norm"]) > 99.0


class TestGradCompression:
    def test_error_feedback_reduces_bias(self):
        rng = np.random.default_rng(0)
        g = {"w": jnp.asarray(rng.normal(size=(256,)).astype(np.float32))}
        err = init_error_state(g)
        acc_plain = np.zeros(256)
        acc_ef = np.zeros(256)
        err_state = err
        for _ in range(50):
            q, scales, _ = compress_gradients(g, init_error_state(g))
            acc_plain += np.asarray(q["w"], np.float32) * float(scales["w"])
            deq, err_state = error_feedback_update(g, err_state)
            acc_ef += np.asarray(deq["w"])
        target = np.asarray(g["w"]) * 50
        assert np.abs(acc_ef - target).max() <= \
            np.abs(acc_plain - target).max() + 1e-5
        # EF accumulation must track the true sum closely
        assert np.abs(acc_ef - target).max() / np.abs(target).max() < 0.01

    def test_compression_ratio(self):
        g = {"w": jnp.ones((1024,), jnp.float32)}
        q, scales, _ = compress_gradients(g, init_error_state(g))
        assert q["w"].dtype == jnp.int8   # 4× fewer bytes over the wire


class TestData:
    def test_deterministic_restart(self):
        cfg = DataConfig(vocab_size=100, seq_len=32, global_batch=4, seed=7)
        it = DataIterator(cfg)
        batches = [next(it) for _ in range(5)]
        it2 = DataIterator(cfg)
        it2.restore({"step": 3})
        b3 = next(it2)
        np.testing.assert_array_equal(batches[3]["tokens"], b3["tokens"])

    def test_local_correlation(self):
        cfg = DataConfig(vocab_size=1000, seq_len=256, global_batch=8)
        b = markov_batch(cfg, 0)
        diffs = np.abs(np.diff(b["tokens"].astype(np.int64), axis=1))
        diffs = np.minimum(diffs, 1000 - diffs)
        # most steps stay within the band
        assert (diffs <= cfg.bandwidth).mean() > 0.8

    def test_labels_are_shifted_tokens(self):
        cfg = DataConfig(vocab_size=50, seq_len=16, global_batch=2)
        b = markov_batch(cfg, 1)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


class TestCheckpoint:
    def test_atomic_roundtrip(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        tree = {"a": jnp.arange(10, dtype=jnp.float32),
                "nested": {"b": jnp.ones((3, 4))}}
        mgr.save(5, tree, extra={"step": 5})
        restored, extra = mgr.restore(tree)
        assert extra["step"] == 5
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.arange(10, dtype=np.float32))

    def test_corruption_falls_back(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        tree = {"a": jnp.arange(4, dtype=jnp.float32)}
        mgr.save(1, tree)
        mgr.save(2, jax.tree.map(lambda x: x + 1, tree))
        # corrupt step 2
        victim = next((tmp_path / "step_00000002").glob("*.npy"))
        data = np.load(victim)
        np.save(victim, data + 99)
        restored, _ = mgr.restore(tree)
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.arange(4, dtype=np.float32))

    def test_gc_keeps_recent(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=2)
        tree = {"a": jnp.zeros(2)}
        for s in (1, 2, 3, 4):
            mgr.save(s, tree)
        assert mgr.all_steps() == [3, 4]

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        tree = {"a": jnp.arange(6, dtype=jnp.float32)}
        mgr.save_async(7, tree, extra={"step": 7})
        mgr.wait()
        assert mgr.latest_step() == 7


class TestFaultTolerance:
    def test_crash_and_restart_resumes(self, tmp_path):
        """Inject a hard crash mid-training; the restarted run must resume
        from the checkpoint and converge to the same final state as an
        uninterrupted run (bit-exact data resume)."""
        env = dict(os.environ, PYTHONPATH=str(ROOT / "src"))
        base = [sys.executable, "-m", "repro.launch.train",
                "--arch", "minicpm-2b", "--reduced", "--steps", "12",
                "--global-batch", "2", "--seq", "64", "--ckpt-every", "4"]
        crash_dir = tmp_path / "crash"
        p = subprocess.run(base + ["--ckpt-dir", str(crash_dir),
                                   "--fail-at-step", "6"],
                           env=env, capture_output=True, text=True,
                           timeout=600)
        assert p.returncode == 17, p.stderr[-800:]
        p2 = subprocess.run(base + ["--ckpt-dir", str(crash_dir)],
                            env=env, capture_output=True, text=True,
                            timeout=600)
        assert p2.returncode == 0, p2.stderr[-800:]
        assert "[restore] resumed from step 4" in p2.stdout

        clean_dir = tmp_path / "clean"
        p3 = subprocess.run(base + ["--ckpt-dir", str(clean_dir)],
                            env=env, capture_output=True, text=True,
                            timeout=600)
        assert p3.returncode == 0, p3.stderr[-800:]

        final_resumed = p2.stdout.strip().splitlines()[-1]
        final_clean = p3.stdout.strip().splitlines()[-1]
        # "final loss: X (first: Y)" → compare X (bit-exact resume)
        assert final_resumed.split()[2] == final_clean.split()[2], \
            (final_resumed, final_clean)


class TestHLOAnalyzer:
    def test_scan_trip_count_scaling(self):
        """The analyzer must multiply while-body FLOPs by the trip count."""
        def step(w, x):
            def body(h, wi):
                return jnp.tanh(h @ wi), ()
            h, _ = jax.lax.scan(body, x, w)
            return h.sum()
        n_layers, dim = 6, 64
        w = jnp.ones((n_layers, dim, dim))
        x = jnp.ones((8, dim))
        compiled = jax.jit(step).lower(w, x).compile()
        stats = H.analyze_hlo_text(compiled.as_text())
        expected = 2 * 8 * dim * dim * n_layers
        assert abs(stats["dot_flops_per_device"] - expected) / expected < 0.01

    def test_collective_detection(self):
        txt = """
ENTRY %main (p: f32[16,16]) -> f32[16,16] {
  %p = f32[16,16]{1,0} parameter(0)
  %ag = f32[32,16]{1,0} all-gather(%p), replica_groups={{0,1}}, dimensions={0}
  %ar = f32[16,16]{1,0} all-reduce(%p), to_apply=%add
  ROOT %r = f32[16,16]{1,0} add(%p, %p)
}
"""
        stats = H.analyze_hlo_text(txt)
        assert stats["collective_counts"].get("all-gather") == 1
        assert stats["collective_counts"].get("all-reduce") == 1
        ag = 32 * 16 * 4
        ar = 16 * 16 * 4 * 2   # ring all-reduce ≈ 2× payload
        assert stats["collective_bytes_by_kind"]["all-gather"] == ag
        assert stats["collective_bytes_by_kind"]["all-reduce"] == ar


@pytest.mark.slow
class TestSmallMeshCompile:
    """Lower + compile representative archs on an 8-device forced-host mesh —
    the fast CI version of the 512-chip dry run (subprocess because device
    count is locked at first jax init)."""

    @pytest.mark.parametrize("arch,shape", [
        ("minicpm-2b", "train_4k"),
        ("mamba2-1.3b", "decode_32k"),
    ])
    def test_cell_compiles_on_8_devices(self, arch, shape, tmp_path):
        code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import repro.launch.mesh as M
def small(*, multi_pod=False):
    return jax.make_mesh((2, 4), ("data", "model"),
                         **M._axis_type_kwargs(2))
M.make_production_mesh = small
import repro.launch.dryrun as D
import dataclasses, repro.configs as C
from repro.models.config import SHAPES
cfg = C.get_reduced("{arch}")
import repro.configs
repro.configs.get_config = lambda a: cfg
SHAPES["{shape}"] = dataclasses.replace(
    SHAPES["{shape}"], seq_len=256, global_batch=4)
r = D.lower_cell("{arch}", "{shape}", multi_pod=False)
assert r["status"] == "ok", r
print("COMPILED", r["chips"])
"""
        env = dict(os.environ, PYTHONPATH=str(ROOT / "src"))
        p = subprocess.run([sys.executable, "-c", code], env=env,
                           capture_output=True, text=True, timeout=600)
        assert p.returncode == 0, p.stderr[-2000:]
        assert "COMPILED 8" in p.stdout
